"""Offline thought-decomposition calibration (paper Algorithm 1).

    PYTHONPATH=src python examples/calibrate_thoughts.py

Runs KDE over per-layer decode-step sparsity traces, selects the tri-modal
layer subset L*, extracts the inter-mode minima as thresholds Theta, and
validates the resulting classifier against the planted ground truth.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import calibrate
from repro.core.thoughts import classify
from repro.data.synthetic import ReasoningTraceGen


def main():
    gen = ReasoningTraceGen(dataset="aime", seed=0)
    planted_lstar = [2, 5, 9, 13]
    print("collecting sparsity traces (16 layers x 8 prompts)...")
    traces = gen.calibration_traces(num_prompts=8, length=3000,
                                    num_layers=16, lstar=planted_lstar)

    res = calibrate(traces, num_thoughts=3, num_calib_layers=4)
    print(f"selected L* = {res.layer_subset} "
          f"(planted tri-modal layers: {planted_lstar})")
    print(f"thresholds Theta = ({res.thresholds[0]:.3f}, "
          f"{res.thresholds[1]:.3f})")
    print("tri-modal hits per layer:",
          {k: v for k, v in sorted(res.per_layer_modes.items())})

    trace = gen.generate(5000)
    pred = np.asarray(classify(jnp.asarray(trace.sparsities),
                               tuple(res.thresholds)))
    acc = float((pred == trace.thought_types).mean())
    print(f"token-level classification accuracy vs planted: {acc:.3f}")


if __name__ == "__main__":
    main()
