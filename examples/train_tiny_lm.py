"""Train a small LM for a few hundred steps with the full production loop:
remat'd train step, AdamW + schedule, atomic checkpoints, auto-resume.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]

Interrupt it (Ctrl-C) and run again: it resumes from the last checkpoint.
"""
import argparse

from repro.config import OptimizerConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.data.synthetic import lm_batches
from repro.training.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    mcfg = get_smoke_config(args.arch)
    cfg = TrainConfig(
        model=mcfg,
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=20,
                                  decay_steps=args.steps),
        seq_len=64, global_batch=8, steps=args.steps,
        checkpoint_dir=args.ckpt_dir, checkpoint_every=50)

    def data_fn(start):
        it = lm_batches(mcfg.vocab_size, cfg.global_batch, cfg.seq_len,
                        seed=11)
        for _ in range(start):
            next(it)
        return it

    res = Trainer(cfg, data_fn).run()
    print(f"\ntrained to step {res.final_step} "
          f"(resumed from {res.resumed_from})")
    print(f"loss: {res.losses[0]:.4f} -> {res.losses[-1]:.4f}")
    print(f"stragglers: {res.straggler_summary}")


if __name__ == "__main__":
    main()
