"""End-to-end driver: batched reasoning-model serving with ThinKV.

    PYTHONPATH=src python examples/serve_reasoning.py [--requests 8]

Continuous batching through the ThinKV engine on a reduced
DeepSeek-R1-Distill-Llama architecture (the paper's model family):
requests stream through fixed slots, each slot's KV cache is
thought-adaptively quantized (TBQ), segment-annealed (TBE), and paged with
in-place slot reuse (CT).

TENSOR-PARALLEL SERVING: the full launcher (``repro.launch.serve``)
accepts ``--mesh model=N`` to shard the engine over a device mesh on the
KV-head axis — pool planes, TBQ buffers, and the fused attention launch
are partitioned per shard while block tables, refcounts, scheduler, and
prefix cache stay replicated, so serving output is BIT-IDENTICAL to the
single-device run.  On a CPU-only host, fake the devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.serve \\
        --requests 5 --slots 3 --temperature 0 \\
        --heads 8 --kv-heads 8 --mesh model=8 --expect-mesh-parity

(``--heads/--kv-heads 8`` make the smoke config head-shardable; real
GQA serving configs need ``kv_heads % N == 0``.  ``--expect-mesh-parity``
replays the trace unsharded and verifies bit-exact logits.)
"""
import argparse
import time

import numpy as np

from repro.config import ServeConfig, ThinKVConfig
from repro.configs import get_smoke_config
from repro.serving.engine import ThinKVEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--budget", type=int, default=64)
    args = ap.parse_args()

    mcfg = get_smoke_config("r1-llama-8b")
    tk = ThinKVConfig(refresh_interval=16, group_size=8, block_size=8,
                      token_budget=args.budget,
                      retention_schedule=(32, 16, 8, 4), min_retention=4,
                      max_segments=128, kmeans_iters=4)
    eng = ThinKVEngine(ServeConfig(model=mcfg, thinkv=tk,
                                   max_seqs=args.slots, temperature=0.7))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, mcfg.vocab_size, int(rng.integers(8, 24)))
               for _ in range(args.requests)]
    eng.submit(prompts, max_new_tokens=args.max_new)

    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0

    print(f"\nserved {len(done)} requests on {args.slots} slots "
          f"in {wall:.1f}s ({eng.metrics['tokens'] / wall:.1f} tok/s "
          f"CPU-reference)")
    for r in done:
        print(f"  req {r.uid}: {len(r.output)} tokens | "
              f"cache {max(r.stats['valid_tokens'])} toks "
              f"({r.stats['footprint_frac'] * 100:.1f}% of FullKV) | "
              f"avg {r.stats['avg_bits']:.2f} bits")


if __name__ == "__main__":
    main()
