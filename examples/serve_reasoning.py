"""End-to-end driver: batched reasoning-model serving with ThinKV.

    PYTHONPATH=src python examples/serve_reasoning.py [--requests 8]

Continuous batching through the ThinKV engine on a reduced
DeepSeek-R1-Distill-Llama architecture (the paper's model family):
requests stream through fixed slots, each slot's KV cache is
thought-adaptively quantized (TBQ), segment-annealed (TBE), and paged with
in-place slot reuse (CT).

STREAMING: ``--stream`` serves the same workload through the asyncio
orchestrator (``repro.serving.orchestrator``) instead of the synchronous
batch loop — each request gets an ``async for token in stream`` iterator
fed while the NEXT device tick is already dispatched, waiting requests
prefill while running ones decode, and per-request TTFT / TPOT /
queue-wait are reported at the end.  The tokens (and every logit behind
them) are bit-identical to the batch path at temperature 0; streaming
changes WHEN you see them, not what they are.  See docs/serving.md.

BEST-OF-N REASONING: ``--samples n`` serves n candidate continuations
per prompt WITHOUT re-prefilling or copying the cache — once a request
is mid-decode, the engine COW-forks its slot (``fork_slot``): every
physical cache block of the prompt + chain-of-thought-so-far is
refcount-shared, the n logical sequences diverge through their own
sampling streams, and only blocks a sequence actually rewrites get
copied (copy-on-write faults).  At temperature 0 every fork reproduces
its parent bit for bit; at temperature > 0 you rank the n finished
candidates with a verifier and keep the best.  ``--ticks-per-dispatch
N`` additionally fuses up to N decode ticks into one on-device
dispatch (sampled tokens never visit the host mid-pack).

TENSOR-PARALLEL SERVING: the full launcher (``repro.launch.serve``)
accepts ``--mesh model=N`` to shard the engine over a device mesh on the
KV-head axis — pool planes, TBQ buffers, and the fused attention launch
are partitioned per shard while block tables, refcounts, scheduler, and
prefix cache stay replicated, so serving output is BIT-IDENTICAL to the
single-device run.  On a CPU-only host, fake the devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.serve \\
        --requests 5 --slots 3 --temperature 0 \\
        --heads 8 --kv-heads 8 --mesh model=8 --expect-mesh-parity

(``--heads/--kv-heads 8`` make the smoke config head-shardable; real
GQA serving configs need ``kv_heads % N == 0``.  ``--expect-mesh-parity``
replays the trace unsharded and verifies bit-exact logits.)
"""
import argparse
import time

import numpy as np

from repro.config import ServeConfig, ThinKVConfig
from repro.configs import get_smoke_config
from repro.serving.engine import ThinKVEngine


def run_streamed(eng, prompts, max_new, samples=1):
    """Streamed serving demo: one consumer task per request drains its
    ``async for`` token stream while the engine is mid-tick on the next
    batch; arrivals are staggered in tick space (request i enters the
    queue after 2*i engine ticks) so prefill genuinely overlaps decode.
    ``samples=n`` attaches n-1 COW-forked sibling streams per request
    (``stream.forks``) — best-of-n over the shared prompt + CoT prefix."""
    import asyncio

    from repro.serving.orchestrator import Orchestrator

    orch = Orchestrator(eng)

    async def consume(stream):
        toks = []
        async for tok in stream:
            toks.append(tok)          # a real server would flush to the
        return stream, toks           # client socket here, mid-tick

    async def go():
        streams = [orch.schedule_arrival(after_tick=2 * i, prompt=p,
                                         max_new_tokens=max_new,
                                         uid=i if samples == 1 else None,
                                         samples_per_slot=samples)
                   for i, p in enumerate(prompts)]
        consumers = [asyncio.ensure_future(consume(s))
                     for parent in streams
                     for s in (parent, *parent.forks)]
        orch.close()
        done = await orch.serve()
        drained = [await c for c in consumers]
        return done, drained, streams

    done, drained, streams = asyncio.run(go())
    for stream, toks in drained:
        assert list(stream.request.output) == list(toks), \
            "stream lost a token"
    return done, orch, streams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--stream", action="store_true",
                    help="serve via the asyncio orchestrator: streaming "
                         "token delivery with staggered arrivals")
    ap.add_argument("--samples", type=int, default=1,
                    help="best-of-n: serve n COW-forked candidate "
                         "continuations per prompt (implies --stream)")
    ap.add_argument("--ticks-per-dispatch", type=int, default=1,
                    help="fuse up to N decode ticks into one on-device "
                         "dispatch (sampling stays on-device)")
    args = ap.parse_args()
    if args.samples > 1:
        args.stream = True            # forks land via the orchestrator

    mcfg = get_smoke_config("r1-llama-8b")
    tk = ThinKVConfig(refresh_interval=16, group_size=8, block_size=8,
                      token_budget=args.budget,
                      retention_schedule=(32, 16, 8, 4), min_retention=4,
                      max_segments=128, kmeans_iters=4)
    eng = ThinKVEngine(ServeConfig(model=mcfg, thinkv=tk,
                                   max_seqs=args.slots, temperature=0.7),
                       ticks_per_dispatch=args.ticks_per_dispatch,
                       allow_forks=args.samples > 1)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, mcfg.vocab_size, int(rng.integers(8, 24)))
               for _ in range(args.requests)]

    t0 = time.time()
    streams = None
    if args.stream:
        done, orch, streams = run_streamed(eng, prompts, args.max_new,
                                           samples=args.samples)
    else:
        eng.submit(prompts, max_new_tokens=args.max_new)
        done = eng.run()
    wall = time.time() - t0

    mode = "streamed" if args.stream else "served"
    print(f"\n{mode} {len(done)} requests on {args.slots} slots "
          f"in {wall:.1f}s ({eng.metrics['tokens'] / wall:.1f} tok/s "
          f"CPU-reference)")
    if args.stream:
        pct = orch.percentiles()
        if "ttft_s" in pct and "tpot_s" in pct:
            print(f"  TTFT p50 {pct['ttft_s']['p50'] * 1e3:.0f}ms / "
                  f"p99 {pct['ttft_s']['p99'] * 1e3:.0f}ms | "
                  f"TPOT p50 {pct['tpot_s']['p50'] * 1e3:.0f}ms | "
                  f"prefill-overlapped-decode="
                  f"{orch.prefill_overlaps_decode()}")
    for r in done:
        print(f"  req {r.uid}: {len(r.output)} tokens | "
              f"cache {max(r.stats['valid_tokens'])} toks "
              f"({r.stats['footprint_frac'] * 100:.1f}% of FullKV) | "
              f"avg {r.stats['avg_bits']:.2f} bits")
    if args.samples > 1:
        m = eng.metrics
        print(f"\nbest-of-{args.samples}: {m['forks']} COW forks shared "
              f"prompt+CoT blocks (peak refcount {m['peak_refcount']}, "
              f"{m['fork_cow_faults']} divergence COW faults)")
        for parent in streams:
            group = [parent, *parent.forks]
            lens = [len(s.request.output) for s in group]
            # a real deployment scores the n candidates with a verifier /
            # reward model here and keeps the argmax
            print(f"  prompt {parent.request.uid}: {len(group)} "
                  f"candidates of {lens} tokens")


if __name__ == "__main__":
    main()
